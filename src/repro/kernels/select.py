"""Blockwise top-M selection for the shortlist scan: device-side select.

The shortlist stage of :class:`repro.index.ClusteredIndex` scores a query
block against a candidate pool (one proxy GEMM) and keeps the best
``max_rerank`` per query.  On the host that is a torch ``mm``/``topk``
pair; on an accelerator the score matrix used to round-trip to the host
for selection — ~0.27 GB per 2048-query block at U=32768.  This module
keeps selection on the device:

* :func:`fused_scan_topm` — the Pallas blockwise-select kernel.  Grid
  ``(Q/bq, N/bn)`` with the candidate axis innermost: each step computes
  one ``q_tile @ proxies_tileᵀ`` score block on the MXU, knocks out
  self-pairs and padding, and folds the block into a VMEM-resident
  running top-``m`` buffer via one canonical ``(-score, id)`` sort over
  ``m_pad + bn`` lanes.  The (Q, N) score matrix is never materialised —
  not even in HBM.  The merge uses ``jax.lax.sort`` inside the kernel
  body; that is exact and runs under interpret mode (this repo's kernel
  validation vehicle — see ``kernels/cluster.py``), while Mosaic lowering
  of in-kernel sorts is unproven and tracked in ROADMAP.md.  Production
  TPU paths that cannot lower it use :func:`scan_topm_xla`.
* :func:`select_topm` — the same running merge over a precomputed score
  matrix (the item index's proxy scorer feeds it device scores that
  already carry the seen-item knockout).
* :func:`scan_topm_xla` — the XLA twin: one jnp GEMM plus
  ``jax.lax.top_k`` (exact; XLA's top_k breaks ties toward the lower
  index, which *is* the canonical ``(-score, id)`` policy), or
  ``jax.lax.approx_max_k`` when ``approx=True`` — TPU's O(N) partial
  reduce, recall < 1 by construction, for latency-bound serving only.

Selection policy — identical across every path and pinned by the oracle
(``ref.select_topm_ref``): descending score, ties broken toward the lower
candidate id, knocked-out slots at ``-inf`` (callers map them to their
padding id).  This is the same canonical order as the exact engines'
``(-score, id)`` sort, so shortlists are bit-identical whether selected
here, by the host torch/numpy scan, or by the degenerate exact path.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro import compat

# MXU-aligned defaults (v5e: 128×128 MXU, 8×128 VREG lanes); bn bounds the
# per-step sort width (m_pad + bn lanes resident in VMEM)
BQ, BN = 256, 1024

_NEG_INF = float("-inf")


def _pad_axis(x, mult, axis, value=0.0):
    rem = x.shape[axis] % mult
    if rem == 0:
        return x
    pad = [(0, 0)] * x.ndim
    pad[axis] = (0, mult - rem)
    return jnp.pad(x, pad, constant_values=value)


def _merge_topm(acc_v, acc_i, s, col, m_pad):
    """Fold one score block into the running buffer: one canonical
    ``(-score, id)`` sort over the concatenation, keep the best m_pad."""
    cat_v = jnp.concatenate([acc_v, s], axis=1)
    cat_i = jnp.concatenate([acc_i, col], axis=1)
    neg_sorted, idx_sorted = jax.lax.sort((-cat_v, cat_i), num_keys=2)
    return -neg_sorted[:, :m_pad], idx_sorted[:, :m_pad]


def _topm_step(s, qid_ref, val_ref, idx_ref, acc_v, acc_i, *, n_j: int,
               n_valid: int, bn: int, m_pad: int):
    """Shared kernel step: init the running buffer on the first column
    block, knock out self/padding slots of this block's scores ``s``,
    fold them into the running canonical top-m, and emit on the last."""
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        acc_v[...] = jnp.full(acc_v.shape, _NEG_INF, jnp.float32)
        acc_i[...] = jnp.full(acc_i.shape, n_valid, jnp.int32)

    col = j * bn + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
    invalid = (col >= n_valid) | (col == qid_ref[...])
    s = jnp.where(invalid, _NEG_INF, s)
    # sentinel policy: every -inf slot (knockout here, or a precomputed
    # knockout in the caller's scores) carries id n_valid, so downstream
    # gathers can never silently index a real row through a dead slot
    col = jnp.where(jnp.isneginf(s), n_valid, col)
    acc_v[...], acc_i[...] = _merge_topm(acc_v[...], acc_i[...], s, col,
                                         m_pad)

    @pl.when(j == n_j - 1)
    def _out():
        val_ref[...] = acc_v[...]
        idx_ref[...] = acc_i[...]


def _scan_kernel(q_ref, p_ref, qid_ref, val_ref, idx_ref, acc_v, acc_i,
                 **kw):
    s = jax.lax.dot_general(
        q_ref[...].astype(jnp.float32), p_ref[...].astype(jnp.float32),
        (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32)
    _topm_step(s, qid_ref, val_ref, idx_ref, acc_v, acc_i, **kw)


def _select_kernel(s_ref, qid_ref, val_ref, idx_ref, acc_v, acc_i, **kw):
    _topm_step(s_ref[...].astype(jnp.float32), qid_ref, val_ref, idx_ref,
               acc_v, acc_i, **kw)


def _m_pad(m: int) -> int:
    return max(128, -(-m // 128) * 128)


@functools.partial(jax.jit, static_argnames=("m", "bq", "bn", "interpret"))
def fused_scan_topm(q: jnp.ndarray, proxies: jnp.ndarray,
                    q_ids: jnp.ndarray, *, m: int, bq: int = BQ,
                    bn: int = BN, interpret: bool = False):
    """(Q, P) query proxies × (N, P) pool proxies → canonical top-``m``
    per query: ``(values (Q, m), ids (Q, m) int32)``.

    ``q_ids``: (Q,) global ids for the self-pair knockout (out-of-range,
    e.g. -1 or N, for padding queries — they never match a column).
    Knocked-out and padding slots come back as ``-inf`` with id ``N``.
    """
    n_q, p = q.shape
    n = proxies.shape[0]
    m = min(m, n)
    mp = _m_pad(m)
    bq_, bn_ = min(bq, _m_pad(n_q)), min(bn, _m_pad(n))
    q_p = _pad_axis(q, bq_, 0)
    prox_p = _pad_axis(proxies, bn_, 0)
    qid_p = _pad_axis(q_ids.astype(jnp.int32).reshape(-1, 1), bq_, 0,
                      value=-1)
    grid = (q_p.shape[0] // bq_, prox_p.shape[0] // bn_)

    vals, ids = pl.pallas_call(
        functools.partial(_scan_kernel, n_j=grid[1], n_valid=n, bn=bn_,
                          m_pad=mp),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bq_, p), lambda i, j: (i, 0)),
            pl.BlockSpec((bn_, p), lambda i, j: (j, 0)),
            pl.BlockSpec((bq_, 1), lambda i, j: (i, 0)),
        ],
        out_specs=[pl.BlockSpec((bq_, mp), lambda i, j: (i, 0)),
                   pl.BlockSpec((bq_, mp), lambda i, j: (i, 0))],
        out_shape=[jax.ShapeDtypeStruct((q_p.shape[0], mp), jnp.float32),
                   jax.ShapeDtypeStruct((q_p.shape[0], mp), jnp.int32)],
        scratch_shapes=[pltpu.VMEM((bq_, mp), jnp.float32),
                        pltpu.VMEM((bq_, mp), jnp.int32)],
        compiler_params=compat.pallas_tpu_compiler_params(
            dimension_semantics=("parallel", "arbitrary")),
        interpret=interpret,
    )(q_p, prox_p, qid_p)
    return vals[:n_q, :m], ids[:n_q, :m]


@functools.partial(jax.jit, static_argnames=("m", "bq", "bn", "interpret"))
def select_topm(scores: jnp.ndarray, q_ids: jnp.ndarray, *, m: int,
                bq: int = BQ, bn: int = BN, interpret: bool = False):
    """Canonical top-``m`` over precomputed (Q, N) scores (running
    blockwise merge, no full-width sort).  Same contract as
    :func:`fused_scan_topm`; pass out-of-range ``q_ids`` when the scores
    already carry their self/seen knockout."""
    n_q, n = scores.shape
    m = min(m, n)
    mp = _m_pad(m)
    bq_, bn_ = min(bq, _m_pad(n_q)), min(bn, _m_pad(n))
    s_p = _pad_axis(_pad_axis(scores, bq_, 0), bn_, 1)
    qid_p = _pad_axis(q_ids.astype(jnp.int32).reshape(-1, 1), bq_, 0,
                      value=-1)
    grid = (s_p.shape[0] // bq_, s_p.shape[1] // bn_)

    vals, ids = pl.pallas_call(
        functools.partial(_select_kernel, n_j=grid[1], n_valid=n, bn=bn_,
                          m_pad=mp),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bq_, bn_), lambda i, j: (i, j)),
            pl.BlockSpec((bq_, 1), lambda i, j: (i, 0)),
        ],
        out_specs=[pl.BlockSpec((bq_, mp), lambda i, j: (i, 0)),
                   pl.BlockSpec((bq_, mp), lambda i, j: (i, 0))],
        out_shape=[jax.ShapeDtypeStruct((s_p.shape[0], mp), jnp.float32),
                   jax.ShapeDtypeStruct((s_p.shape[0], mp), jnp.int32)],
        scratch_shapes=[pltpu.VMEM((bq_, mp), jnp.float32),
                        pltpu.VMEM((bq_, mp), jnp.int32)],
        compiler_params=compat.pallas_tpu_compiler_params(
            dimension_semantics=("parallel", "arbitrary")),
        interpret=interpret,
    )(s_p, qid_p)
    return vals[:n_q, :m], ids[:n_q, :m]


@functools.partial(jax.jit, static_argnames=("m", "approx",
                                             "recall_target"))
def scan_topm_xla(q: jnp.ndarray, proxies: jnp.ndarray,
                  q_ids: jnp.ndarray, *, m: int, approx: bool = False,
                  recall_target: float = 0.95):
    """The XLA twin of :func:`fused_scan_topm`: one device GEMM feeding
    ``jax.lax.top_k`` (exact — XLA breaks ties toward the lower index,
    the canonical policy) or ``jax.lax.approx_max_k`` (``approx=True``:
    TPU's blockwise partial reduce, recall < 1, never used where the
    bit-parity contract applies)."""
    n = proxies.shape[0]
    m = min(m, n)
    s = jnp.matmul(q, proxies.T, precision=jax.lax.Precision.HIGHEST)
    col = jnp.arange(n, dtype=jnp.int32)[None, :]
    s = jnp.where(col == q_ids.astype(jnp.int32)[:, None], _NEG_INF, s)
    if approx:
        vals, ids = jax.lax.approx_max_k(s, m,
                                         recall_target=recall_target)
    else:
        vals, ids = jax.lax.top_k(s, m)
    ids = jnp.where(jnp.isneginf(vals), n, ids)   # sentinel policy
    return vals, ids.astype(jnp.int32)
