"""Fused centroid-distance Pallas TPU kernel for the clustered ANN index.

The candidate-generation stage of :mod:`repro.index` assigns every user row
to its nearest k-means centroid and shortlists the ``n_probe`` nearest
clusters per query.  Both need the (m, C) squared-Euclidean distance matrix

    dist[i, j] = ||x_i - c_j||^2 = ||x_i||^2 - 2 x_i.c_j + ||c_j||^2

between mean-centered rating rows ``x`` and centroids ``c``.  The fused
kernel accumulates the cross term and both squared norms in one K-blocked
VMEM pass — one read of each operand tile instead of three XLA ops that each
re-stream the rows from HBM — and applies the epilogue in-register.

Grid: (M/bm, C/bn, D/bk) with the K axis innermost ("arbitrary" — it carries
the accumulators); M/C are "parallel".  Interpret mode runs the same kernel
on CPU and is what the tests validate against the jnp oracle in
``repro.kernels.ref``; production CPU paths use the oracle directly (see
``centroid_distances`` below), Mosaic compiles it on real TPU.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro import compat

# default MXU-aligned tile sizes (v5e: 128×128 MXU, 8×128 VREG lanes)
BM, BN, BK = 256, 256, 512


def _dot_t(a, b):
    """a (m,k) · b (n,k)ᵀ with f32 accumulation on the MXU."""
    return jax.lax.dot_general(
        a, b, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32)


def _dist_kernel(x_ref, c_ref, out_ref, acc_dot, acc_xx, acc_cc, *, n_k: int):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _zero():
        for r in (acc_dot, acc_xx, acc_cc):
            r[...] = jnp.zeros_like(r)

    x = x_ref[...].astype(jnp.float32)
    c = c_ref[...].astype(jnp.float32)
    acc_dot[...] += _dot_t(x, c)
    acc_xx[...] += jnp.sum(x * x, axis=1, keepdims=True)        # (bm, 1)
    acc_cc[...] += jnp.sum(c * c, axis=1, keepdims=True).T      # (1, bn)

    @pl.when(k == n_k - 1)
    def _epilogue():
        d = acc_xx[...] - 2.0 * acc_dot[...] + acc_cc[...]
        out_ref[...] = jnp.maximum(d, 0.0)   # clamp float-cancellation noise


def _pad_to(x, mult, axis):
    rem = x.shape[axis] % mult
    if rem == 0:
        return x
    pad = [(0, 0)] * x.ndim
    pad[axis] = (0, mult - rem)
    return jnp.pad(x, pad)


@functools.partial(jax.jit, static_argnames=("bm", "bn", "bk", "interpret"))
def fused_centroid_distances(x: jnp.ndarray, c: jnp.ndarray, *,
                             bm: int = BM, bn: int = BN, bk: int = BK,
                             interpret: bool = False) -> jnp.ndarray:
    """(m, D) rows × (n, D) centroids → (m, n) squared Euclidean distances."""
    m, d = x.shape
    n = c.shape[0]
    bm_, bn_, bk_ = min(bm, m), min(bn, n), min(bk, d)
    x_p = _pad_to(_pad_to(x, bm_, 0), bk_, 1)
    c_p = _pad_to(_pad_to(c, bn_, 0), bk_, 1)
    mp, dp = x_p.shape
    np_ = c_p.shape[0]
    grid = (mp // bm_, np_ // bn_, dp // bk_)

    out = pl.pallas_call(
        functools.partial(_dist_kernel, n_k=grid[2]),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm_, bk_), lambda i, j, k: (i, k)),
            pl.BlockSpec((bn_, bk_), lambda i, j, k: (j, k)),
        ],
        out_specs=pl.BlockSpec((bm_, bn_), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mp, np_), jnp.float32),
        scratch_shapes=[pltpu.VMEM((bm_, bn_), jnp.float32),
                        pltpu.VMEM((bm_, 1), jnp.float32),
                        pltpu.VMEM((1, bn_), jnp.float32)],
        compiler_params=compat.pallas_tpu_compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(x_p, c_p)
    return out[:m, :n]


def centroid_distances(x: jnp.ndarray, c: jnp.ndarray, *,
                       use_kernel: bool = False,
                       interpret: bool = False) -> jnp.ndarray:
    """Backend-dispatching wrapper: fused kernel on TPU, jnp oracle elsewhere.

    The interpret-mode kernel is a correctness vehicle, not a fast path —
    the index only routes through it when ``use_kernel`` is set (auto-on
    for real TPU; tests force it with ``interpret=True`` at toy sizes).
    """
    if use_kernel:
        return fused_centroid_distances(x, c, interpret=interpret)
    from repro.kernels import ref
    return ref.centroid_distances_ref(x, c)
