"""Pallas TPU kernels for the framework's compute hot spots.

Each kernel has a pure-jnp oracle in ``ref.py`` and a backend-dispatching
wrapper in ``ops.py``; kernels are validated in interpret mode on CPU and
target Mosaic on real TPU.
"""

from repro.kernels.ops import embedding_bag, flash_attention, pairwise_similarity

__all__ = ["embedding_bag", "flash_attention", "pairwise_similarity"]
