"""Pallas TPU kernels for the framework's compute hot spots.

Each kernel has a pure-jnp oracle in ``ref.py`` and a backend-dispatching
wrapper in ``ops.py``; kernels are validated in interpret mode on CPU and
target Mosaic on real TPU.
"""

from repro.kernels.cluster import centroid_distances, fused_centroid_distances
from repro.kernels.ops import embedding_bag, flash_attention, pairwise_similarity

__all__ = ["centroid_distances", "embedding_bag", "flash_attention",
           "fused_centroid_distances", "pairwise_similarity"]
