"""Fused co-rated Gram rerank Pallas TPU kernel + host BLAS twin.

The exact rerank of the clustered index scores each query against its
shortlisted candidates with the *true* similarity measure.  The sparse
gather form (``repro.index.clustered._rerank_sparse``) walks an
``(M, nnz)`` sub-block per query — optimal when a fast random-access
gather exists (CPU caches).  On TPU there is no such gather: XLA lowers
it to per-element dynamic slices, and the six Gram statistics each
re-stream the gathered block from HBM.

This kernel is the MXU formulation.  Queries are grouped (by taste
cluster — neighbors of one cluster shortlist largely the same
candidates), the group's candidate-union rows are gathered **once**, and
all num/den statistics for the whole ``(group, union)`` block come out of
one K-blocked VMEM pass:

    n     = Σ_i 1[vq>0]·1[rc>0]      dot  = Σ_i vq·rc
    sum_a = Σ_i vq·1[rc>0]           sum_b = Σ_i 1[vq>0]·rc
    sq_a  = Σ_i vq²·1[rc>0]          sq_b  = Σ_i 1[vq>0]·rc²

Every statistic carries a query-side factor, so terms vanish off the
query's rated items — full-width candidate rows give exactly the sparse
co-rated sums (the paper's per-pair loop, lifted onto the MXU).  Cosine's
full-vector candidate norms and jaccard's rated counts cannot be derived
from a column-compressed union block, so they stream in precomputed
(one cheap global pass, shapes ``(1, Kc)``).

For integer-valued rating matrices (MovieLens 1..5) every Gram sum is an
exactly-representable f32 integer regardless of accumulation order, so
the kernel, the jnp oracle (``repro.kernels.ref.rerank_scores_ref``), the
host BLAS twin below, and ``_rerank_sparse`` all agree **bit for bit** —
the equivalence the oracle tests pin.

Grid: (G/bm, Kc/bn, J/bk), K innermost ("arbitrary" — it carries the
accumulators); group/union axes are "parallel".  Interpret mode runs on
CPU for tests; production CPU reranking uses :func:`rerank_scores_host`
(OpenBLAS) because at CPU memory bandwidth the bucketed int8 gather walk
or the BLAS twin win over interpret-mode Pallas by construction.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro import compat
from repro.core import similarity as sim

_EPS = 1e-8
MEASURES = ("jaccard", "cosine", "pcc", "pcc_sig")

# default MXU-aligned tile sizes (v5e: 128×128 MXU, 8×128 VREG lanes)
BM, BN, BK = 128, 256, 512


def _dot_t(a, b):
    """a (m,k) · b (n,k)ᵀ with f32 accumulation on the MXU."""
    return jax.lax.dot_general(
        a, b, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32)


def _rerank_kernel(q_ref, c_ref, cn_ref, cc_ref, out_ref, *accs,
                   n_k: int, measure: str, beta: float):
    (acc_n, acc_dot, acc_sa, acc_sb, acc_qa, acc_qb,
     acc_qn, acc_qc) = accs
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _zero():
        for r in accs:
            r[...] = jnp.zeros_like(r)

    vq = q_ref[...].astype(jnp.float32)            # (bm, bk) query values
    rc = c_ref[...].astype(jnp.float32)            # (bn, bk) candidate rows
    mq = (vq > 0).astype(jnp.float32)
    mc = (rc > 0).astype(jnp.float32)

    if measure == "cosine":
        acc_dot[...] += _dot_t(vq, rc)
        acc_qn[...] += jnp.sum(vq * vq, axis=1, keepdims=True)   # (bm, 1)
    elif measure == "jaccard":
        acc_n[...] += _dot_t(mq, mc)
        acc_qc[...] += jnp.sum(mq, axis=1, keepdims=True)
    else:                                          # pcc / pcc_sig
        acc_n[...] += _dot_t(mq, mc)
        acc_dot[...] += _dot_t(vq, rc)
        acc_sa[...] += _dot_t(vq, mc)
        acc_sb[...] += _dot_t(mq, rc)
        acc_qa[...] += _dot_t(vq * vq, mc)
        acc_qb[...] += _dot_t(mq, rc * rc)

    @pl.when(k == n_k - 1)
    def _epilogue():
        if measure == "cosine":
            nq = jnp.sqrt(acc_qn[...])
            denom = nq * cn_ref[...]
            out_ref[...] = acc_dot[...] / jnp.maximum(denom, _EPS)
        elif measure == "jaccard":
            n = acc_n[...]
            union = acc_qc[...] + cc_ref[...] - n
            out_ref[...] = n / jnp.maximum(union, _EPS)
        else:
            n = acc_n[...]
            cov = n * acc_dot[...] - acc_sa[...] * acc_sb[...]
            var_a = n * acc_qa[...] - acc_sa[...] * acc_sa[...]
            var_b = n * acc_qb[...] - acc_sb[...] * acc_sb[...]
            denom = jnp.sqrt(jnp.maximum(var_a, 0.0)
                             * jnp.maximum(var_b, 0.0))
            valid = (n >= 2) & (denom > _EPS)
            pcc = jnp.clip(cov / jnp.maximum(denom, _EPS), -1.0, 1.0)
            s = jnp.where(valid, (pcc + 1.0) * 0.5, 0.0)
            if measure == "pcc_sig":
                s = s * (jnp.minimum(n, beta) / beta)
            out_ref[...] = s


def _pad_to(x, mult, axis):
    rem = x.shape[axis] % mult
    if rem == 0:
        return x
    pad = [(0, 0)] * x.ndim
    pad[axis] = (0, mult - rem)
    return jnp.pad(x, pad)


@functools.partial(jax.jit, static_argnames=(
    "measure", "beta", "bm", "bn", "bk", "interpret"))
def fused_rerank_scores(q_vals: jnp.ndarray, cand_rows: jnp.ndarray,
                        cand_norms: jnp.ndarray, cand_counts: jnp.ndarray,
                        *, measure: str = "cosine", beta: float = 50.0,
                        bm: int = BM, bn: int = BN, bk: int = BK,
                        interpret: bool = False) -> jnp.ndarray:
    """Exact similarity of a query group against a candidate union.

    ``q_vals``: (G, J) query rating rows (0 = unrated); ``cand_rows``:
    (Kc, J) candidate rows over the same item axis (int8 or f32 — the
    kernel casts tiles in-register, so the int8 gather source streams 4×
    less HBM); ``cand_norms``/``cand_counts``: (Kc,) full-row L2 norms and
    rated counts.  Returns (G, Kc) scores under ``measure`` — the same
    formulas as ``_rerank_sparse``; self/padding masking is the caller's.
    """
    if measure not in MEASURES:
        raise ValueError(f"unknown measure {measure!r}; want one of "
                         f"{MEASURES}")
    g, j = q_vals.shape
    kc = cand_rows.shape[0]
    bm_, bn_, bk_ = min(bm, g), min(bn, kc), min(bk, j)
    q_p = _pad_to(_pad_to(q_vals, bm_, 0), bk_, 1)
    c_p = _pad_to(_pad_to(cand_rows, bn_, 0), bk_, 1)
    cn_p = _pad_to(cand_norms[None, :].astype(jnp.float32), bn_, 1)
    cc_p = _pad_to(cand_counts[None, :].astype(jnp.float32), bn_, 1)
    gp, jp = q_p.shape
    kp = c_p.shape[0]
    grid = (gp // bm_, kp // bn_, jp // bk_)

    out = pl.pallas_call(
        functools.partial(_rerank_kernel, n_k=grid[2], measure=measure,
                          # reprolint: disable=host-transfer -- beta is a static Python scalar baked into the kernel closure, never traced
                          beta=float(beta)),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm_, bk_), lambda i, j_, k: (i, k)),
            pl.BlockSpec((bn_, bk_), lambda i, j_, k: (j_, k)),
            pl.BlockSpec((1, bn_), lambda i, j_, k: (0, j_)),
            pl.BlockSpec((1, bn_), lambda i, j_, k: (0, j_)),
        ],
        out_specs=pl.BlockSpec((bm_, bn_), lambda i, j_, k: (i, j_)),
        out_shape=jax.ShapeDtypeStruct((gp, kp), jnp.float32),
        scratch_shapes=[pltpu.VMEM((bm_, bn_), jnp.float32)] * 6
        + [pltpu.VMEM((bm_, 1), jnp.float32)] * 2,
        compiler_params=compat.pallas_tpu_compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(q_p, c_p, cn_p, cc_p)
    return out[:g, :kc]


@functools.partial(jax.jit, static_argnames=("measure", "beta"))
def rerank_scores_xla(q_vals: jnp.ndarray, cand_rows: jnp.ndarray,
                      cand_norms: jnp.ndarray, cand_counts: jnp.ndarray,
                      *, measure: str = "cosine",
                      beta: float = 50.0) -> jnp.ndarray:
    """XLA twin of :func:`fused_rerank_scores`: the same union-Gram
    statistics as one jitted jnp pass — the fused query pipeline's rerank
    stage wherever the Pallas kernel does not run.  Delegates to the jnp
    oracle (``ref.rerank_scores_ref``), so the twin is the oracle by
    construction; for integer rating matrices it is bit-identical to the
    kernel, the host BLAS twin, and the sparse gather walk.
    """
    if measure not in MEASURES:
        raise ValueError(f"unknown measure {measure!r}; want one of "
                         f"{MEASURES}")
    from repro.kernels import ref
    return ref.rerank_scores_ref(q_vals, cand_rows, cand_norms,
                                 cand_counts, measure=measure, beta=beta)


def rerank_scores_host(q_vals: np.ndarray, cand_rows: np.ndarray,
                       cand_norms: np.ndarray, cand_counts: np.ndarray,
                       *, measure: str = "cosine",
                       beta: float = 50.0) -> np.ndarray:
    """Host twin of :func:`fused_rerank_scores` on OpenBLAS.

    Same inputs/outputs, numpy f32 throughout.  One sgemm for cosine and
    jaccard, six (stacked) for pcc — for integer rating matrices every
    Gram sum is an exact f32 integer, so the result is bit-identical to
    the kernel, the jnp oracle, and ``_rerank_sparse``.
    """
    if measure not in MEASURES:
        raise ValueError(f"unknown measure {measure!r}; want one of "
                         f"{MEASURES}")
    vq = np.ascontiguousarray(q_vals, np.float32)
    rc = np.ascontiguousarray(cand_rows, np.float32)
    if measure == "cosine":
        dot = vq @ rc.T
        nq = np.sqrt(np.einsum("ij,ij->i", vq, vq))[:, None]
        return dot / np.maximum(nq * cand_norms[None, :], _EPS)
    mq = (vq > 0).astype(np.float32)
    mc = (rc > 0).astype(np.float32)
    if measure == "jaccard":
        n = mq @ mc.T
        union = mq.sum(1)[:, None] + cand_counts[None, :] - n
        return n / np.maximum(union, _EPS)
    n = mq @ mc.T
    dot = vq @ rc.T
    sum_a = vq @ mc.T
    sum_b = mq @ rc.T
    sq_a = (vq * vq) @ mc.T
    sq_b = mq @ (rc * rc).T
    cov = n * dot - sum_a * sum_b
    var_a = n * sq_a - sum_a * sum_a
    var_b = n * sq_b - sum_b * sum_b
    denom = np.sqrt(np.maximum(var_a, 0.0) * np.maximum(var_b, 0.0))
    valid = (n >= 2) & (denom > _EPS)
    pcc = np.clip(cov / np.maximum(denom, _EPS), -1.0, 1.0)
    s = np.where(valid, (pcc + 1.0) * np.float32(0.5), np.float32(0.0))
    if measure == "pcc_sig":
        s = s * (np.minimum(n, np.float32(beta)) / np.float32(beta))
    return s.astype(np.float32, copy=False)
