"""repro: mesh-parallel memory-based collaborative filtering in JAX.

Reproduction + scale-out of "An Efficient Multi-threaded Collaborative
Filtering Approach in Recommendation System" (Hasan, 2024), plus the
substrate for the 10 assigned architectures.  See DESIGN.md.
"""

__version__ = "1.0.0"
