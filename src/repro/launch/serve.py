"""Serving launcher: fit the CF model and serve batched recommendations.

    PYTHONPATH=src python -m repro.launch.serve --requests 128
    PYTHONPATH=src python -m repro.launch.serve --engine facade \\
        --recommend-mode approx          # two-stage item-index serving
"""

from __future__ import annotations

import argparse
import time

import jax.numpy as jnp
import numpy as np

from repro.core import CFConfig, UserCF
from repro.data import load_ml1m_synthetic
from repro.serving.engine import BatchingServer


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--users", type=int, default=1024)
    ap.add_argument("--items", type=int, default=512)
    ap.add_argument("--requests", type=int, default=128)
    ap.add_argument("--max-batch", type=int, default=32)
    ap.add_argument("--topn", type=int, default=10)
    ap.add_argument("--engine", choices=("legacy", "facade"),
                    default="legacy")
    ap.add_argument("--measure", default="pcc",
                    choices=("jaccard", "cosine", "pcc", "pcc_sig"))
    ap.add_argument("--recommend-mode", choices=("exact", "approx"),
                    default="exact",
                    help="facade engine only: approx serves through the "
                         "two-stage item index")
    args = ap.parse_args()

    train, _, _ = load_ml1m_synthetic(n_users=args.users,
                                      n_items=args.items)
    tr = jnp.asarray(train)
    if args.engine == "facade":
        from repro.core import CFEngine
        engine = CFEngine(tr, measure=args.measure, k=40, block_size=256,
                          recommend_mode=args.recommend_mode).fit()
        server = BatchingServer(engine, max_batch=args.max_batch,
                                topn=args.topn)
    else:
        cf = UserCF(CFConfig(measure=args.measure, top_k=40,
                             block_size=256))
        cf.fit(tr)
        server = BatchingServer(cf, tr, max_batch=args.max_batch,
                                topn=args.topn)
    server.start()
    t0 = time.perf_counter()
    futs = [server.submit(int(u)) for u in
            np.random.default_rng(0).integers(0, args.users, args.requests)]
    res = [f.result(timeout=120) for f in futs]
    dt = time.perf_counter() - t0
    server.stop()
    lat = sorted(r.latency_ms for r in res)
    print(f"{len(res)} requests, {len(res) / dt:.0f} req/s, "
          f"p50 {lat[len(lat) // 2]:.1f} ms, "
          f"p99 {lat[int(0.99 * len(lat))]:.1f} ms, "
          f"{server.n_batches} batches")


if __name__ == "__main__":
    main()
