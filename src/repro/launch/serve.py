"""Serving launcher: fit the CF model and serve batched recommendations.

    PYTHONPATH=src python -m repro.launch.serve --requests 128
    PYTHONPATH=src python -m repro.launch.serve --engine facade \\
        --recommend-mode approx          # two-stage item-index serving

Telemetry: the server publishes into the process-wide ``repro.obs``
registry here (so index/engine metrics and serving metrics land in one
dump); ``--stats-interval`` logs a periodic ``stats()`` line while the
run is in flight and ``--metrics-dump PATH`` writes the final registry
snapshot as the flat JSON metrics artifact.

Fault tolerance (README § Fault tolerance & graceful degradation):
``--deadline-ms`` / ``--max-queue`` exercise the request lifecycle,
``--ladder`` enables the degradation state machine, and
``--chaos-at-batch N`` injects a transient fault at batch N so the
supervised retry shows up in the stats line::

    PYTHONPATH=src python -m repro.launch.serve --engine facade \\
        --max-queue 64 --deadline-ms 200 --ladder --chaos-at-batch 2
"""

from __future__ import annotations

import argparse
import threading
import time

import jax.numpy as jnp
import numpy as np

from repro import obs
from repro.core import CFConfig, UserCF
from repro.data import load_ml1m_synthetic
from repro.serving.engine import BatchingServer


def _stats_line(server: BatchingServer) -> str:
    s = server.stats()
    line = (f"requests={s['n_requests']} batches={s['n_batches']} "
            f"p50={s['latency_p50_ms']:.1f}ms p99={s['latency_p99_ms']:.1f}ms "
            f"queue={s['queue_wait_mean_ms']:.1f}ms "
            f"compute={s['compute_mean_ms']:.1f}ms "
            f"fill={s['mean_batch_fill']:.2f} "
            f"depth={s['mean_queue_depth']:.1f} "
            f"health={s['health']}")
    if s["n_failures"] or s["n_shed"] or s["n_deadline_exceeded"]:
        line += (f" failures={s['n_failures']} retries={s['n_retries']} "
                 f"recoveries={s['n_recoveries']} shed={s['n_shed']} "
                 f"deadline={s['n_deadline_exceeded']}")
    return line


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--users", type=int, default=1024)
    ap.add_argument("--items", type=int, default=512)
    ap.add_argument("--requests", type=int, default=128)
    ap.add_argument("--max-batch", type=int, default=32)
    ap.add_argument("--topn", type=int, default=10)
    ap.add_argument("--engine", choices=("legacy", "facade"),
                    default="legacy")
    ap.add_argument("--measure", default="pcc",
                    choices=("jaccard", "cosine", "pcc", "pcc_sig"))
    ap.add_argument("--recommend-mode", choices=("exact", "approx"),
                    default="exact",
                    help="facade engine only: approx serves through the "
                         "two-stage item index")
    ap.add_argument("--stats-interval", type=float, default=0.0,
                    help="seconds between periodic stats() log lines "
                         "(0 disables)")
    ap.add_argument("--metrics-dump", default=None,
                    help="write the final metrics-registry snapshot "
                         "(fit + serving) to this JSON path")
    ap.add_argument("--deadline-ms", type=float, default=0.0,
                    help="per-request deadline; expired-in-queue requests "
                         "resolve with DeadlineExceeded (0 disables)")
    ap.add_argument("--max-queue", type=int, default=0,
                    help="admission bound: submits past it shed with "
                         "Overloaded (0 = unbounded)")
    ap.add_argument("--ladder", action="store_true",
                    help="enable the HEALTHY/DEGRADED/SHEDDING "
                         "degradation ladder")
    ap.add_argument("--degrade-p99-ms", type=float, default=50.0)
    ap.add_argument("--shed-p99-ms", type=float, default=200.0)
    ap.add_argument("--chaos-at-batch", type=int, default=0,
                    help="inject a transient fault at this batch number "
                         "(0 disables) — exercises the supervised retry")
    ap.add_argument("--max-restarts", type=int, default=3,
                    help="retry budget per faulted batch")
    args = ap.parse_args()

    from repro.distributed.fault_tolerance import (FaultInjector,
                                                   RecoveryPolicy)
    from repro.serving.engine import DegradationLadder
    ft_kw = dict(
        max_queue=args.max_queue,
        recovery=RecoveryPolicy(max_restarts=args.max_restarts),
        fault_injector=(FaultInjector(fail_at_steps=(args.chaos_at_batch,))
                        if args.chaos_at_batch > 0 else None),
        ladder=(DegradationLadder(degrade_p99_ms=args.degrade_p99_ms,
                                  shed_p99_ms=args.shed_p99_ms)
                if args.ladder else None))

    train, _, _ = load_ml1m_synthetic(n_users=args.users,
                                      n_items=args.items)
    tr = jnp.asarray(train)
    if args.engine == "facade":
        from repro.core import CFEngine
        engine = CFEngine(tr, measure=args.measure, k=40, block_size=256,
                          recommend_mode=args.recommend_mode).fit()
        server = BatchingServer(engine, max_batch=args.max_batch,
                                topn=args.topn, registry=obs.registry(),
                                **ft_kw)
    else:
        cf = UserCF(CFConfig(measure=args.measure, top_k=40,
                             block_size=256))
        cf.fit(tr)
        server = BatchingServer(cf, tr, max_batch=args.max_batch,
                                topn=args.topn, registry=obs.registry(),
                                **ft_kw)
    server.start()

    stop_log = threading.Event()
    if args.stats_interval > 0:
        def logger():
            while not stop_log.wait(args.stats_interval):
                print(f"[stats] {_stats_line(server)}", flush=True)
        threading.Thread(target=logger, daemon=True).start()

    from repro.serving.engine import DeadlineExceeded, Overloaded
    t0 = time.perf_counter()
    deadline = args.deadline_ms if args.deadline_ms > 0 else None
    futs, shed = [], 0
    for u in np.random.default_rng(0).integers(0, args.users,
                                               args.requests):
        try:
            futs.append(server.submit(int(u), deadline_ms=deadline))
        except Overloaded:
            shed += 1
    res, expired = [], 0
    for f in futs:
        try:
            res.append(f.result(timeout=120))
        except DeadlineExceeded:
            expired += 1
    dt = time.perf_counter() - t0
    stop_log.set()
    server.stop()
    extra = (f", {shed} shed, {expired} expired"
             if shed or expired else "")
    print(f"{len(res)} requests{extra}, {len(res) / dt:.0f} req/s, "
          f"{_stats_line(server)}")
    if args.metrics_dump:
        obs.export_metrics(args.metrics_dump)
        print(f"wrote {args.metrics_dump}")


if __name__ == "__main__":
    main()
