"""launch subpackage."""
