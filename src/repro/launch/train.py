"""Training launcher: any registered arch, fault-tolerant loop, local mesh.

Production use (per host, under the cluster scheduler):
    python -m repro.launch.train --arch llama3_2_1b --steps 1000 \\
        --ckpt-dir /ckpt/run42
This container (CPU): run the smoke config of any arch end-to-end:
    PYTHONPATH=src python -m repro.launch.train --arch qwen3_moe_30b_a3b \\
        --smoke --steps 30
"""

from __future__ import annotations

import argparse
import importlib

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.registry import get_arch
from repro.data import batches as db
from repro.data import graph as dg
from repro.training.optimizer import get_optimizer
from repro.training.train_loop import TrainLoopConfig, make_train_step, run


def _loss_and_batch(arch, cfg, seed_base: int):
    """(loss_fn(params, batch), batches(step)) for any family."""
    if arch.kind == "lm":
        from repro.models import transformer as tx
        def loss_fn(p, b):
            return tx.loss_fn(cfg, p, b)
        def batches(i):
            return {k: jnp.asarray(v) for k, v in
                    db.lm_batch(4, 64, cfg.vocab, seed=seed_base + i).items()}
        params = tx.init_params(cfg, jax.random.PRNGKey(0))
        return loss_fn, batches, params
    if arch.kind == "gnn":
        from repro.models import egnn
        g = dg.synthetic_graph(dg.GraphSpec(n_nodes=256, n_edges=1024,
                                            d_feat=cfg.d_feat,
                                            n_classes=cfg.d_out))
        batch = {k: jnp.asarray(v) for k, v in g.items()}
        def loss_fn(p, b):
            return egnn.loss_fn(cfg, p, b)
        params = egnn.init_params(cfg, jax.random.PRNGKey(0))
        return loss_fn, (lambda i: batch), params
    if arch.kind == "recsys":
        model = importlib.import_module(f"repro.models.{arch.model}")
        if arch.model == "bert4rec":
            def batches(i):
                return {k: jnp.asarray(v) for k, v in db.bert4rec_batch(
                    16, cfg.seq_len, cfg.n_items, cfg.mask_token,
                    seed=seed_base + i).items()}
        else:
            def batches(i):
                return {k: jnp.asarray(v) for k, v in db.recsys_batch(
                    32, cfg.field_sizes, n_dense=getattr(cfg, "n_dense", 0),
                    seed=seed_base + i).items()}
        def loss_fn(p, b):
            return model.loss_fn(cfg, p, b)
        params = model.init_params(cfg, jax.random.PRNGKey(0))
        return loss_fn, batches, params
    raise ValueError(f"use examples/train_cf_movielens.py for {arch.kind}")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true",
                    help="use the reduced smoke config (CPU-runnable)")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--compression", action="store_true")
    args = ap.parse_args()

    arch = get_arch(args.arch)
    cfg = arch.smoke_config() if args.smoke else arch.config
    loss_fn, batches, params = _loss_and_batch(arch, cfg, seed_base=0)
    n = sum(x.size for x in jax.tree_util.tree_leaves(params))
    print(f"arch={arch.name} kind={arch.kind} params={n / 1e6:.2f}M "
          f"optimizer={arch.optimizer}")

    opt = get_optimizer(arch.optimizer)
    state = opt.init(params)
    if args.compression:
        from repro.training.compression import init_compression
        state = {"opt": state, "ef": init_compression(params)}
    step = jax.jit(make_train_step(loss_fn, opt,
                                   compression=args.compression))

    res = run(step, params, state, batches,
              TrainLoopConfig(total_steps=args.steps, checkpoint_every=20,
                              checkpoint_dir=args.ckpt_dir))
    first = np.mean(res.losses[:5]) if res.losses else float("nan")
    last = np.mean(res.losses[-5:]) if res.losses else float("nan")
    print(f"steps={res.final_step} loss {first:.4f} → {last:.4f} "
          f"restarts={res.restarts}")


if __name__ == "__main__":
    main()
