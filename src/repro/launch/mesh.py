"""Production mesh construction.

A function (not a module constant) so importing never touches jax device
state.  Single pod: (16, 16) = 256 chips, axes (data, model).  Multi-pod:
(2, 16, 16) = 512 chips with the leading ``pod`` axis as outer data
parallelism (the slow inter-pod DCI links only ever carry gradient
all-reduces, never layer-wise TP traffic).
"""

from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes,
                         axis_types=(jax.sharding.AxisType.Auto,) * len(axes))


def make_flat_mesh(*, multi_pod: bool = False, axis: str = "data"):
    """Same devices as one ring — the CF engines' 1-axis partition view."""
    n = 512 if multi_pod else 256
    return jax.make_mesh((n,), (axis,),
                         axis_types=(jax.sharding.AxisType.Auto,))


def make_local_mesh(shape=None, axes=None):
    """Mesh over whatever local devices exist (tests / examples)."""
    n = len(jax.devices())
    if shape is None:
        shape, axes = (n,), ("data",)
    return jax.make_mesh(shape, axes,
                         axis_types=(jax.sharding.AxisType.Auto,) * len(axes))
