"""Production mesh construction.

A function (not a module constant) so importing never touches jax device
state.  Single pod: (16, 16) = 256 chips, axes (data, model).  Multi-pod:
(2, 16, 16) = 512 chips with the leading ``pod`` axis as outer data
parallelism (the slow inter-pod DCI links only ever carry gradient
all-reduces, never layer-wise TP traffic).

All meshes go through ``repro.compat.make_mesh`` so the ``axis_types``
kwarg drift between jax 0.4.x and ≥0.5 is handled in one place.
"""

from __future__ import annotations

import jax

from repro import compat


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return compat.make_mesh(shape, axes)


def make_flat_mesh(*, multi_pod: bool = False, axis: str = "data"):
    """Same devices as one ring — the CF engines' 1-axis partition view."""
    n = 512 if multi_pod else 256
    return compat.make_mesh((n,), (axis,))


def make_local_mesh(shape=None, axes=None):
    """Mesh over whatever local devices exist (tests / examples)."""
    n = len(jax.devices())
    if shape is None:
        shape, axes = (n,), ("data",)
    return compat.make_mesh(shape, axes)
