"""Step builders: (arch × shape-cell × mesh) → a lowerable jitted callable.

Each builder returns a ``StepPlan``: the step function, example inputs
(ShapeDtypeStructs — nothing allocated), and explicit in/out shardings.
``dryrun.py`` lowers these; ``train.py``/``serve.py`` execute them with real
arrays at reduced scale.
"""

from __future__ import annotations

import dataclasses
import importlib
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.registry import ArchSpec, ShapeCell, input_specs
from repro.distributed import sharding as shd
from repro.models import transformer as tx
from repro.training.optimizer import get_optimizer

i32 = jnp.int32
f32 = jnp.float32


@dataclasses.dataclass
class StepPlan:
    name: str
    fn: Callable
    example_args: Tuple[Any, ...]
    in_shardings: Tuple[Any, ...]
    out_shardings: Any
    donate_argnums: Tuple[int, ...] = ()
    flops_note: str = ""


def _ns(mesh, spec):
    return NamedSharding(mesh, shd._sanitize(mesh, spec))


def build_step(arch: ArchSpec, cell: ShapeCell, mesh: Mesh) -> StepPlan:
    if arch.kind == "lm":
        return _lm_step(arch, cell, mesh)
    if arch.kind == "gnn":
        return _gnn_step(arch, cell, mesh)
    if arch.kind == "recsys":
        return _recsys_step(arch, cell, mesh)
    if arch.kind == "cf":
        return _cf_step(arch, cell, mesh)
    raise ValueError(arch.kind)


# ---------------------------------------------------------------------------
# LM family
# ---------------------------------------------------------------------------

def _lm_step(arch: ArchSpec, cell: ShapeCell, mesh: Mesh) -> StepPlan:
    cfg = arch.config
    sc = shd.make_ctx(mesh)
    baxes = shd.batch_axes(mesh)
    pspecs = tx.param_specs(cfg)
    params_sh = shd.to_shardings(mesh, pspecs)
    params_shapes = jax.eval_shape(
        lambda: tx.init_params(cfg, jax.random.PRNGKey(0)))
    inputs = input_specs(arch, cell)

    if cell.step == "train":
        opt = get_optimizer(arch.optimizer)
        opt_specs = opt.state_specs(pspecs)
        opt_sh = shd.to_shardings(mesh, opt_specs)
        opt_shapes = jax.eval_shape(opt.init, params_shapes)
        batch_sh = {"tokens": _ns(mesh, P(baxes, None)),
                    "labels": _ns(mesh, P(baxes, None))}
        mb = cfg.microbatch

        if mb == 1:
            def step(params, opt_state, batch):
                loss, grads = jax.value_and_grad(
                    lambda p: tx.loss_fn(cfg, p, batch, sc))(params)
                params, opt_state = opt.update(params, grads, opt_state)
                return params, opt_state, loss
        else:
            # gradient accumulation: scan over µbatches, mean the grads —
            # bounds activation live-set to one µbatch (see §Perf iter 2)
            def step(params, opt_state, batch):
                bsz, seq = batch["tokens"].shape
                toks = batch["tokens"].reshape(mb, bsz // mb, seq)
                labs = batch["labels"].reshape(mb, bsz // mb, seq)

                def ubatch(carry, xs):
                    gacc, ltot = carry
                    t, l = xs
                    loss, g = jax.value_and_grad(
                        lambda p: tx.loss_fn(
                            cfg, p, {"tokens": t, "labels": l}, sc))(params)
                    gacc = jax.tree_util.tree_map(jnp.add, gacc, g)
                    return (gacc, ltot + loss), ()

                zeros = jax.tree_util.tree_map(jnp.zeros_like, params)
                (gacc, ltot), _ = jax.lax.scan(
                    ubatch, (zeros, jnp.float32(0.0)), (toks, labs))
                grads = jax.tree_util.tree_map(lambda x: x / mb, gacc)
                params, opt_state = opt.update(params, grads, opt_state)
                return params, opt_state, ltot / mb

        return StepPlan(
            name=f"{arch.name}:{cell.name}", fn=step,
            example_args=(params_shapes, opt_shapes, inputs),
            in_shardings=(params_sh, opt_sh, batch_sh),
            out_shardings=(params_sh, opt_sh, _ns(mesh, P())),
            donate_argnums=(0, 1))

    if cell.step == "prefill":
        tok_sh = {"tokens": _ns(mesh, P(baxes, None))}
        cache_sh = shd.to_shardings(mesh, tx.cache_specs(cfg, baxes))

        def step(params, batch):
            return tx.prefill(cfg, params, batch["tokens"], sc)

        return StepPlan(
            name=f"{arch.name}:{cell.name}", fn=step,
            example_args=(params_shapes, inputs),
            in_shardings=(params_sh, tok_sh),
            out_shardings=(_ns(mesh, P(baxes, "model")), cache_sh))

    # decode
    cache_sh = shd.to_shardings(mesh, tx.cache_specs(cfg, baxes))
    in_sh = (params_sh,
             {"tokens": _ns(mesh, P(baxes, None)), "cache": cache_sh})

    def step(params, batch):
        return tx.decode_step(cfg, params, batch["tokens"], batch["cache"],
                              sc)

    return StepPlan(
        name=f"{arch.name}:{cell.name}", fn=step,
        example_args=(params_shapes, inputs),
        in_shardings=in_sh,
        out_shardings=(_ns(mesh, P(baxes, "model")), cache_sh),
        donate_argnums=())


# ---------------------------------------------------------------------------
# GNN
# ---------------------------------------------------------------------------

def _gnn_step(arch: ArchSpec, cell: ShapeCell, mesh: Mesh) -> StepPlan:
    from repro.models import egnn as eg
    import dataclasses as dc
    cfg = dc.replace(arch.config, d_feat=cell.dims["d_feat"])
    inputs = input_specs(arch, cell)
    sc = shd.make_ctx(mesh, dp_over_all=True)
    baxes = shd.batch_axes(mesh)
    opt = get_optimizer(arch.optimizer)
    pspecs = eg.param_specs(cfg)
    params_sh = shd.to_shardings(mesh, pspecs)
    params_shapes = jax.eval_shape(
        lambda: eg.init_params(cfg, jax.random.PRNGKey(0)))
    opt_sh = shd.to_shardings(mesh, opt.state_specs(pspecs))
    opt_shapes = jax.eval_shape(opt.init, params_shapes)

    if cell.name == "molecule":
        batch_sh = {k: _ns(mesh, P(baxes, *((None,) * (len(v.shape) - 1))))
                    for k, v in inputs.items()}
        shard_edges = False
    else:
        # nodes replicated, edge list sharded over every device
        eaxes = tuple(mesh.axis_names)
        batch_sh = {
            "feat": _ns(mesh, P(None, None)),
            "coord": _ns(mesh, P(None, None)),
            "edges": _ns(mesh, P(None, eaxes)),
            "labels": _ns(mesh, P(None)),
        }
        shard_edges = True
        sc = dc.replace(sc, batch=eaxes)

    def step(params, opt_state, batch):
        loss, grads = jax.value_and_grad(
            lambda p: eg.loss_fn(cfg, p, batch, sc,
                                 shard_edges=shard_edges))(params)
        params, opt_state = opt.update(params, grads, opt_state)
        return params, opt_state, loss

    return StepPlan(
        name=f"{arch.name}:{cell.name}", fn=step,
        example_args=(params_shapes, opt_shapes, inputs),
        in_shardings=(params_sh, opt_sh, batch_sh),
        out_shardings=(params_sh, opt_sh, _ns(mesh, P())),
        donate_argnums=(0, 1))


# ---------------------------------------------------------------------------
# RecSys
# ---------------------------------------------------------------------------

def _recsys_step(arch: ArchSpec, cell: ShapeCell, mesh: Mesh) -> StepPlan:
    model = importlib.import_module(f"repro.models.{arch.model}")
    cfg = arch.config
    sc = shd.make_ctx(mesh, dp_over_all=True)
    aaxes = tuple(mesh.axis_names)
    inputs = input_specs(arch, cell)
    pspecs = model.param_specs(cfg, aaxes) if arch.model != "bert4rec" \
        else model.param_specs(cfg)
    params_sh = shd.to_shardings(mesh, pspecs)
    params_shapes = jax.eval_shape(
        lambda: model.init_params(cfg, jax.random.PRNGKey(0)))

    def batch_shard(v):
        if v.shape and v.shape[0] > 1 and v.shape[0] % 512 == 0:
            return _ns(mesh, P(aaxes, *((None,) * (len(v.shape) - 1))))
        return _ns(mesh, P(*((None,) * len(v.shape))))

    batch_sh = {k: batch_shard(v) for k, v in inputs.items()}

    if cell.step == "train":
        opt = get_optimizer(arch.optimizer)
        opt_sh = shd.to_shardings(mesh, opt.state_specs(pspecs))
        opt_shapes = jax.eval_shape(opt.init, params_shapes)

        def step(params, opt_state, batch):
            loss, grads = jax.value_and_grad(
                lambda p: model.loss_fn(cfg, p, batch, mesh, sc))(params)
            params, opt_state = opt.update(params, grads, opt_state)
            return params, opt_state, loss

        return StepPlan(
            name=f"{arch.name}:{cell.name}", fn=step,
            example_args=(params_shapes, opt_shapes, inputs),
            in_shardings=(params_sh, opt_sh, batch_sh),
            out_shardings=(params_sh, opt_sh, _ns(mesh, P())),
            donate_argnums=(0, 1))

    if cell.step == "serve":
        fwd = model.serve_scores if arch.model == "bert4rec" \
            else model.forward

        def step(params, batch):
            return fwd(cfg, params, batch, mesh, sc)

        out_spec = P(aaxes, None) if arch.model == "bert4rec" else P(aaxes)
        return StepPlan(
            name=f"{arch.name}:{cell.name}", fn=step,
            example_args=(params_shapes, inputs),
            in_shardings=(params_sh, batch_sh),
            out_shardings=_ns(mesh, out_spec))

    # retrieval
    def step(params, batch):
        return model.retrieval_score(cfg, params, batch, mesh, sc)

    return StepPlan(
        name=f"{arch.name}:{cell.name}", fn=step,
        example_args=(params_shapes, inputs),
        in_shardings=(params_sh, batch_sh),
        out_shardings=_ns(mesh, P(aaxes)))


# ---------------------------------------------------------------------------
# CF (the paper's own architecture; runs on the flat 1-axis mesh)
# ---------------------------------------------------------------------------

def _cf_step(arch: ArchSpec, cell: ShapeCell, mesh: Mesh) -> StepPlan:
    from repro.core import engine
    cfg = arch.config
    inputs = input_specs(arch, cell)
    axis = mesh.axis_names[0]
    rat_sh = {"ratings": _ns(mesh, P(axis, None))}
    topk_sh = _ns(mesh, P(axis, None))

    if cell.step == "cf_fit":
        fit_engine = engine.sharded_topk if cfg.engine == "sharded" \
            else engine.ring_sharded_topk

        def step(batch):
            return fit_engine(
                batch["ratings"], cfg.top_k, mesh, measure=cfg.measure,
                axis=axis, block_size=cfg.block_size)

        return StepPlan(
            name=f"{arch.name}:{cell.name}", fn=step,
            example_args=(inputs,), in_shardings=(rat_sh,),
            out_shardings=(topk_sh, topk_sh))

    # cf_predict
    u = cell.dims["users"]
    k = cfg.top_k

    def step(batch, scores, idx):
        return engine.ring_sharded_predict(batch["ratings"], scores, idx,
                                           mesh, axis=axis)

    return StepPlan(
        name=f"{arch.name}:{cell.name}", fn=step,
        example_args=(inputs,
                      jax.ShapeDtypeStruct((u, k), f32),
                      jax.ShapeDtypeStruct((u, k), i32)),
        in_shardings=(rat_sh, topk_sh, topk_sh),
        out_shardings=_ns(mesh, P(axis, None)))
