import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch × shape × mesh) cell.

The two lines above MUST stay the first statements in this module: jax locks
the device count at first init, and only the dry-run wants 512 placeholder
CPU devices (smoke tests and benches see the real single device).

Per cell this records, to results/dryrun/<mesh>/<arch>__<shape>.json:
  * compiled.memory_analysis()  — per-device bytes (proves it fits)
  * compiled.cost_analysis()    — per-device HLO flops / bytes accessed
  * per-collective byte totals parsed from the post-SPMD HLO text
The roofline report (benchmarks/roofline.py) is derived from these files.

Usage:
  python -m repro.launch.dryrun --arch llama3_2_1b --shape train_4k
  python -m repro.launch.dryrun --all              # every cell, subprocesses
  python -m repro.launch.dryrun --all --multipod   # (2,16,16) pass
"""

import argparse
import json
import re
import subprocess
import sys
import time
from collections import defaultdict
from pathlib import Path

RESULTS = Path(__file__).resolve().parents[3] / "results" / "dryrun"

_DTYPE_BYTES = {
    "f64": 8, "s64": 8, "u64": 8, "c64": 8,
    "f32": 4, "s32": 4, "u32": 4,
    "bf16": 2, "f16": 2, "s16": 2, "u16": 2,
    "f8e4m3fn": 1, "f8e5m2": 1, "s8": 1, "u8": 1, "pred": 1,
}

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_COLL_RE = re.compile(
    r"=\s*((?:\([^)]*\)|\S+))\s+"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?\(")


def shape_bytes(text: str) -> int:
    """Sum byte sizes of every typed shape literal in ``text``."""
    total = 0
    for dtype, dims in _SHAPE_RE.findall(text):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += int(n * _DTYPE_BYTES[dtype])
    return total


def collective_bytes(hlo_text: str) -> dict:
    """Per-collective-type byte totals (output-shape sizes, per device).

    ``-done`` ops carry no shape of their own in post-SPMD HLO; ``-start``
    and sync forms are counted once each via the output shape to the left of
    the op name.
    """
    out = defaultdict(lambda: {"count": 0, "bytes": 0})
    for line in hlo_text.splitlines():
        m = _COLL_RE.search(line)
        if not m:
            continue
        shape_txt, kind = m.group(1), m.group(2)
        b = shape_bytes(shape_txt)
        out[kind]["count"] += 1
        out[kind]["bytes"] += b
    return dict(out)


def _compile_plan(arch, cell, mesh):
    import jax
    from repro.launch.steps import build_step
    plan = build_step(arch, cell, mesh)
    jitted = jax.jit(plan.fn, in_shardings=plan.in_shardings,
                     out_shardings=plan.out_shardings,
                     donate_argnums=plan.donate_argnums)
    with mesh:
        lowered = jitted.lower(*plan.example_args)
        compiled = lowered.compile()
    return compiled


# §Perf variants: config transformations measured against the baseline
def _apply_variant(arch, name):
    import dataclasses as dc
    if not name:
        return arch
    cfg = arch.config
    if name == "gatherw":
        cfg = dc.replace(cfg, gather_weights_at_use=True)
    elif name.startswith("gatherw_ub"):
        cfg = dc.replace(cfg, gather_weights_at_use=True,
                         microbatch=int(name.split("ub")[1]))
    elif name.startswith("ub"):
        cfg = dc.replace(cfg, microbatch=int(name[2:]))
    elif name.startswith("offl_ub"):
        cfg = dc.replace(cfg, gather_weights_at_use=True,
                         remat_policy="offload_psum",
                         microbatch=int(name.split("ub")[1]))
    elif name == "replicated":        # CF: shared-memory engine
        cfg = dc.replace(cfg, engine="sharded")
    elif name.startswith("cf"):       # cf1.0 etc: MoE capacity factor
        m = dc.replace(cfg.moe, capacity_factor=float(name[2:]))
        cfg = dc.replace(cfg, moe=m)
    elif name.startswith("blk"):      # CF block size
        cfg = dc.replace(cfg, block_size=int(name[3:]))
    else:
        raise ValueError(f"unknown variant {name!r}")
    return dc.replace(arch, config=cfg)


def run_cell(arch_name: str, shape_name: str, multi_pod: bool,
             variant: str = "") -> dict:
    import jax
    from repro.configs.registry import get_arch
    from repro.launch.mesh import make_flat_mesh, make_production_mesh

    arch = _apply_variant(get_arch(arch_name), variant)
    cell = arch.cell(shape_name)
    if cell.skip:
        return {"arch": arch.name, "shape": cell.name, "skipped": cell.skip}

    mesh = make_flat_mesh(multi_pod=multi_pod) if arch.kind == "cf" \
        else make_production_mesh(multi_pod=multi_pod)
    t0 = time.time()
    compiled = _compile_plan(arch, cell, mesh)
    t_compile = time.time() - t0
    t_lower = 0.0

    ca = compiled.cost_analysis() or {}
    ma = compiled.memory_analysis()
    hlo = compiled.as_text()
    colls = collective_bytes(hlo)

    # loop-aware re-derivation: XLA's CPU cost_analysis counts while-loop
    # bodies once; the hlo_cost parser multiplies by known_trip_count
    from repro.launch import hlo_cost
    parsed = hlo_cost.analyze(hlo)

    rec = {
        "arch": arch.name,
        "shape": cell.name,
        "variant": variant or "baseline",
        "step": cell.step,
        "mesh": "multi_pod(2,16,16)" if multi_pod else "single_pod(16,16)",
        "n_devices": 512 if multi_pod else 256,
        "lower_s": round(t_lower, 2),
        "compile_s": round(t_compile, 2),
        "flops_per_device": ca.get("flops"),
        "bytes_accessed_per_device": ca.get("bytes accessed"),
        "memory": {
            "argument_bytes": ma.argument_size_in_bytes,
            "output_bytes": ma.output_size_in_bytes,
            "temp_bytes": ma.temp_size_in_bytes,
            "alias_bytes": ma.alias_size_in_bytes,
            "code_bytes": ma.generated_code_size_in_bytes,
        },
        "collectives": colls,
        "collective_bytes_total": sum(v["bytes"] for v in colls.values()),
        "hlo_parsed": parsed,
        "hlo_lines": hlo.count("\n"),
    }
    print(json.dumps(rec, indent=2))
    print(f"MEMORY_ANALYSIS: {ma}")
    return rec


def _cell_list():
    from repro.configs.registry import ASSIGNED, get_arch
    cells = []
    for name in list(ASSIGNED) + ["cf_movielens"]:
        arch = get_arch(name)
        for c in arch.shapes:
            cells.append((name, c.name, bool(c.skip)))
    return cells


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--variant", default="")
    ap.add_argument("--multipod", action="store_true")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--timeout", type=int, default=3000)
    args = ap.parse_args()

    mesh_tag = "multi_pod" if args.multipod else "single_pod"
    outdir = RESULTS / mesh_tag
    outdir.mkdir(parents=True, exist_ok=True)

    if not args.all:
        rec = run_cell(args.arch, args.shape, args.multipod, args.variant)
        suffix = f"__{args.variant}" if args.variant else ""
        out = outdir / f"{args.arch}__{args.shape}{suffix}.json"
        out.write_text(json.dumps(rec, indent=2))
        return

    # run every cell in its own subprocess: isolates compile memory and
    # makes the sweep resumable (skip cells that already have results)
    failures = []
    for arch_name, shape_name, skipped in _cell_list():
        out = outdir / f"{arch_name}__{shape_name}.json"
        if out.exists() and not args.force:
            print(f"[skip-done] {arch_name}:{shape_name}")
            continue
        if skipped:
            from repro.configs.registry import get_arch
            cell = get_arch(arch_name).cell(shape_name)
            out.write_text(json.dumps(
                {"arch": arch_name, "shape": shape_name,
                 "skipped": cell.skip}, indent=2))
            print(f"[skip-cell] {arch_name}:{shape_name}: {cell.skip}")
            continue
        cmd = [sys.executable, "-m", "repro.launch.dryrun",
               "--arch", arch_name, "--shape", shape_name]
        if args.multipod:
            cmd.append("--multipod")
        print(f"[run] {arch_name}:{shape_name} ({mesh_tag})", flush=True)
        t0 = time.time()
        try:
            r = subprocess.run(cmd, capture_output=True, text=True,
                               timeout=args.timeout,
                               env={**os.environ, "PYTHONPATH": "src"})
        except subprocess.TimeoutExpired:
            failures.append((arch_name, shape_name, "timeout"))
            print(f"  TIMEOUT after {args.timeout}s")
            continue
        if r.returncode != 0:
            failures.append((arch_name, shape_name, r.stderr[-2000:]))
            print(f"  FAILED ({time.time()-t0:.0f}s):\n{r.stderr[-2000:]}")
        else:
            print(f"  ok ({time.time()-t0:.0f}s)")
    if failures:
        print(f"\n{len(failures)} failures:")
        for a, s, e in failures:
            print(f"  {a}:{s}: {e.splitlines()[-1] if e.splitlines() else e}")
        sys.exit(1)
    print("\nALL CELLS PASSED")


if __name__ == "__main__":
    main()
