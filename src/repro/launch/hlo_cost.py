"""Loop-aware cost model over post-SPMD HLO text.

XLA's ``compiled.cost_analysis()`` on the CPU backend counts a while-loop
body ONCE, so a scan-over-layers transformer reports ~1/L of its real flops.
This module re-derives per-device costs from the HLO text itself:

  * parse every computation, tracking each op's output shape by name so
    operand shapes can be resolved (CPU HLO dumps don't inline them),
  * flops: dot ops (2·|out|·K with K from the lhs contracting dims),
    elementwise/reduce ops (|out|),
  * bytes: operand + output sizes at op granularity (fusion interiors are
    excluded — the fusion call site's operands/outputs are the buffers that
    actually touch memory),
  * collective bytes per type (output-shape sizes),
  * recurse through fusion/call/while/conditional edges, multiplying while
    bodies by their ``known_trip_count`` annotation.

The result is the HLO_FLOPs / HLO_bytes / collective_bytes basis of the
roofline analysis (EXPERIMENTS.md §Roofline).
"""

from __future__ import annotations

import dataclasses
import re
from collections import defaultdict
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "f64": 8, "s64": 8, "u64": 8, "c64": 8, "c128": 16,
    "f32": 4, "s32": 4, "u32": 4,
    "bf16": 2, "f16": 2, "s16": 2, "u16": 2,
    "f8e4m3fn": 1, "f8e5m2": 1, "s8": 1, "u8": 1, "pred": 1,
    "s4": 1, "u4": 1, "token": 0, "opaque": 0,
}

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_COMP_HDR = re.compile(r"^(?:ENTRY\s+)?%?([\w\.\-]+)\s*\(")
_OP_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?([\w\.\-]+)\s*=\s*(\([^()]*\)|\S+)\s+([\w\-]+)\(")
_NAME_RE = re.compile(r"%([\w\.\-]+)")
_CALLED = re.compile(r"(?:calls|body|to_apply)=%?([\w\.\-]+)")
_BRANCHES = re.compile(r"branch_computations=\{([^}]*)\}")
_TRIP = re.compile(r'known_trip_count[^}]*?"?n"?[=:]"?(\d+)"?')
_CONTRACT = re.compile(r"lhs_contracting_dims=\{([0-9,]*)\}")

COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
               "collective-permute")

_ZERO_COST = {
    "parameter", "constant", "tuple", "get-tuple-element", "bitcast",
    "after-all", "partition-id", "replica-id",
}


def _shape_elems_bytes(text: str) -> Tuple[int, int]:
    elems = bytes_ = 0
    for dtype, dims in _SHAPE_RE.findall(text):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        elems += n
        bytes_ += n * _DTYPE_BYTES[dtype]
    return elems, bytes_


def _shape_dims(text: str) -> List[int]:
    m = _SHAPE_RE.search(text)
    if not m:
        return []
    return [int(d) for d in m.group(2).split(",") if d]


@dataclasses.dataclass
class Cost:
    flops: float = 0.0
    bytes: float = 0.0
    coll: Dict[str, float] = dataclasses.field(
        default_factory=lambda: defaultdict(float))
    unknown_loops: int = 0

    def add(self, other: "Cost", scale: float = 1.0):
        self.flops += other.flops * scale
        self.bytes += other.bytes * scale
        for k, v in other.coll.items():
            self.coll[k] += v * scale
        self.unknown_loops += other.unknown_loops


def _split_operands(line: str) -> str:
    """Text inside the op's outermost parens (the operand list)."""
    eq = line.find(" = ")
    start = line.find("(", eq if eq >= 0 else 0)
    if start < 0:
        return ""
    depth = 0
    for i in range(start, len(line)):
        if line[i] == "(":
            depth += 1
        elif line[i] == ")":
            depth -= 1
            if depth == 0:
                return line[start + 1:i]
    return line[start + 1:]


class HloCostModel:
    def __init__(self, hlo_text: str):
        self.computations: Dict[str, List[str]] = {}
        self.shapes: Dict[str, Dict[str, str]] = {}
        self.entry: Optional[str] = None
        self._parse(hlo_text)
        self._memo: Dict[str, Cost] = {}

    def _parse(self, text: str):
        cur: Optional[str] = None
        for line in text.splitlines():
            stripped = line.strip()
            if cur is None:
                m = _COMP_HDR.match(stripped)
                if m and stripped.endswith("{") and "->" in stripped:
                    cur = m.group(1)
                    self.computations[cur] = []
                    self.shapes[cur] = {}
                    if stripped.startswith("ENTRY"):
                        self.entry = cur
            else:
                if stripped == "}":
                    cur = None
                    continue
                self.computations[cur].append(line)
                m = _OP_RE.match(line)
                if m:
                    self.shapes[cur][m.group(1)] = m.group(2)
        if self.entry is None and self.computations:
            for name in self.computations:
                if name.startswith("main"):
                    self.entry = name
                    break
            else:
                self.entry = next(iter(self.computations))

    def _operand_bytes(self, comp: str, operands_txt: str) -> Tuple[int, int]:
        """(elems, bytes) of named operands, resolved via the shape table."""
        table = self.shapes[comp]
        elems = bytes_ = 0
        for name in _NAME_RE.findall(operands_txt):
            shp = table.get(name)
            if shp:
                e, b = _shape_elems_bytes(shp)
                elems += e
                bytes_ += b
        # inline-shaped operands (older dumps)
        e, b = _shape_elems_bytes(operands_txt)
        elems += e
        bytes_ += b
        return elems, bytes_

    def _op_cost(self, comp: str, line: str) -> Cost:
        c = Cost()
        m = _OP_RE.match(line)
        if not m:
            return c
        _, out_shape_txt, op = m.group(1), m.group(2), m.group(3)
        if op in _ZERO_COST:
            return c
        out_elems, out_bytes = _shape_elems_bytes(out_shape_txt)
        operands_txt = _split_operands(line)
        # strip attributes that follow operands but live inside metadata
        in_elems, in_bytes = self._operand_bytes(comp, operands_txt)

        base_op = op[:-6] if op.endswith("-start") else op
        if base_op in COLLECTIVES:
            c.coll[base_op] += out_bytes
            c.bytes += out_bytes + in_bytes
            return c
        if op.endswith("-done"):
            return c

        if op == "while":
            called = _CALLED.findall(line)          # body (and to_apply-less)
            trip = _TRIP.search(line)
            n = int(trip.group(1)) if trip else 1
            if not trip:
                c.unknown_loops += 1
            for name in called:
                c.add(self.cost_of(name), scale=n)
            return c
        if op in ("fusion", "call", "async-start", "reduce", "scatter",
                  "select-and-scatter", "map", "sort", "reduce-window"):
            mm = _CALLED.search(line)
            if mm:
                sub = self.cost_of(mm.group(1))
                if op == "fusion":
                    # fusion interiors: flops only; buffers are loop-local
                    c.flops += sub.flops
                    for k, v in sub.coll.items():
                        c.coll[k] += v
                    c.unknown_loops += sub.unknown_loops
                elif op in ("reduce", "scatter", "reduce-window", "sort",
                            "select-and-scatter", "map"):
                    c.flops += float(out_elems)      # applied per element
                else:
                    c.add(sub)
            c.bytes += out_bytes + in_bytes
            return c
        if op == "conditional":
            mb = _BRANCHES.search(line)
            if mb:
                subs = [self.cost_of(b.strip().lstrip("%"))
                        for b in mb.group(1).split(",")]
                if subs:
                    best = max(subs, key=lambda s: s.flops)
                    c.add(best)
            c.bytes += out_bytes + in_bytes
            return c
        if op == "dot":
            mm = _CONTRACT.search(line)
            k_size = 1
            if mm:
                dims = [int(d) for d in mm.group(1).split(",") if d]
                names = _NAME_RE.findall(operands_txt)
                lhs_shape = self.shapes[comp].get(names[0]) if names else None
                if lhs_shape is None:
                    mfirst = _SHAPE_RE.search(operands_txt)
                    lhs_shape = mfirst.group(0) if mfirst else None
                if lhs_shape:
                    lhs_dims = _shape_dims(lhs_shape)
                    for d in dims:
                        if d < len(lhs_dims):
                            k_size *= lhs_dims[d]
            c.flops += 2.0 * out_elems * k_size
            c.bytes += out_bytes + in_bytes
            return c
        if op == "convolution":
            c.flops += 2.0 * out_elems * max(in_elems // max(out_elems, 1), 1)
            c.bytes += out_bytes + in_bytes
            return c
        # generic elementwise / copy / dynamic-slice / gather / iota …
        c.flops += float(out_elems)
        c.bytes += out_bytes + in_bytes
        return c

    def cost_of(self, comp: str) -> Cost:
        if comp in self._memo:
            return self._memo[comp]
        self._memo[comp] = Cost()          # break cycles defensively
        total = Cost()
        for line in self.computations.get(comp, ()):
            total.add(self._op_cost(comp, line))
        self._memo[comp] = total
        return total

    def entry_cost(self) -> Cost:
        return self.cost_of(self.entry)


def analyze(hlo_text: str) -> Dict:
    model = HloCostModel(hlo_text)
    c = model.entry_cost()
    return {
        "flops": c.flops,
        "bytes": c.bytes,
        "collective_bytes": dict(c.coll),
        "collective_bytes_total": float(sum(c.coll.values())),
        "unknown_trip_count_loops": c.unknown_loops,
    }
